"""Bench-trend gate: diff fresh benchmark artifacts against committed
baselines (``benchmarks/baselines/``).

The repo's bench trajectory starts here: every ``bench-smoke`` CI run
produces the same JSON artifacts the baselines were generated from
(``sharded_lookup.json``, ``pareto_frontier.json``,
``training_time.json``, ``kernel_roofline.json``,
``write_workload.json``, ``serve_slo.json`` at smoke scale), and this
tool diffs them:

* **trace counts — exact.**  The one-trace-per-(kind, backend)
  invariant is the repo's core compile-cost contract; a silent retrace
  regression changes these counts and fails the gate immediately.
* **structure — exact.**  The set of measured configurations (kinds ×
  backends × modes × shard counts, candidate grids, metric names) must
  match; a silently dropped sweep leg fails the gate.
* **latency — generous ratio.**  CI machines vary wildly, so latency
  fields only fail when they drift beyond ``--tolerance`` (default 8×
  either way) — this catches order-of-magnitude perf cliffs, not noise.
* **exactness flags — exact.**  ``kernel/pallas_smoke/exact`` and the
  candidates' ``exact`` flags must stay 1/true.

Run from the repo root after producing fresh artifacts::

    python -m benchmarks.trend --baselines benchmarks/baselines \\
        sharded_lookup.json pareto_frontier.json kernel_roofline.json \\
        write_workload.json

Refreshing baselines after an *intentional* change (new sweep leg, new
kernel, trace-count change) is one command per artifact — rerun the
benchmark with the CI flags and copy the JSON into
``benchmarks/baselines/``; the PR diff then shows exactly what moved.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _ratio_ok(fresh: float, base: float, tol: float) -> bool:
    if base <= 0 or fresh <= 0:
        return fresh == base
    r = fresh / base
    return (1.0 / tol) <= r <= tol


def _check_traces(name: str, fresh: dict, base: dict) -> list:
    fails = []
    ft, bt = fresh.get("trace_counts", {}), base.get("trace_counts", {})
    if ft != bt:
        extra = sorted(set(ft) - set(bt))
        missing = sorted(set(bt) - set(ft))
        changed = sorted(k for k in set(ft) & set(bt) if ft[k] != bt[k])
        fails.append(
            f"{name}: trace counts diverged from baseline "
            f"(new={extra}, gone={missing}, changed={[(k, bt[k], ft[k]) for k in changed]})"
        )
    if fresh.get("total_traces") != base.get("total_traces"):
        fails.append(
            f"{name}: total_traces {fresh.get('total_traces')} != "
            f"baseline {base.get('total_traces')}"
        )
    return fails


def _check_sharded_lookup(name: str, fresh: dict, base: dict, tol: float) -> list:
    fails = _check_traces(name, fresh, base)
    key = lambda r: (r["kind"], r["backend"], r["mode"], r["n_shards"])
    fr = {key(r): r for r in fresh.get("results", [])}
    br = {key(r): r for r in base.get("results", [])}
    if set(fr) != set(br):
        fails.append(
            f"{name}: measured configurations changed "
            f"(new={sorted(set(fr) - set(br))}, gone={sorted(set(br) - set(fr))})"
        )
    for k in sorted(set(fr) & set(br)):
        if not _ratio_ok(fr[k]["us_per_query"], br[k]["us_per_query"], tol):
            fails.append(
                f"{name}: {k} latency {fr[k]['us_per_query']:.3g}us vs baseline "
                f"{br[k]['us_per_query']:.3g}us exceeds {tol}x tolerance"
            )
    return fails


def _check_pareto_frontier(name: str, fresh: dict, base: dict, tol: float) -> list:
    fails = _check_traces(name, fresh, base)
    fr, br = fresh.get("reports", {}), base.get("reports", {})
    if set(fr) != set(br):
        fails.append(f"{name}: report set changed ({sorted(fr)} vs {sorted(br)})")
    ckey = lambda c: (c["kind"], json.dumps(c.get("params", {}), sort_keys=True))
    for rep in sorted(set(fr) & set(br)):
        fc = {ckey(c): c for c in fr[rep]["candidates"]}
        bc = {ckey(c): c for c in br[rep]["candidates"]}
        if set(fc) != set(bc):
            fails.append(f"{name}/{rep}: candidate grid changed")
        inexact = [k for k, c in fc.items() if not c.get("exact", False)]
        if inexact:
            fails.append(f"{name}/{rep}: inexact candidates {inexact}")
        for k in sorted(set(fc) & set(bc)):
            if not _ratio_ok(fc[k]["ns_per_query"], bc[k]["ns_per_query"], tol):
                fails.append(
                    f"{name}/{rep}: {k[0]} latency {fc[k]['ns_per_query']:.3g}ns vs "
                    f"baseline {bc[k]['ns_per_query']:.3g}ns exceeds {tol}x tolerance"
                )
        if set(fr[rep].get("budget_picks", {})) != set(br[rep].get("budget_picks", {})):
            fails.append(f"{name}/{rep}: budget-pick set changed")
    if "fit" in base and "fit" not in fresh:
        fails.append(f"{name}: baseline has a fit gate section but the fresh run does not")
    if fresh.get("fit", {}).get("vmap_exact", 1) != 1:
        fails.append(f"{name}: fit/vmap_exact != 1")
    return fails


def _check_kernel_roofline(name: str, fresh: dict, base: dict, tol: float) -> list:
    fails = _check_traces(name, fresh, base)
    fm, bm = fresh.get("metrics", {}), base.get("metrics", {})
    if set(fm) != set(bm):
        fails.append(
            f"{name}: metric set changed "
            f"(new={sorted(set(fm) - set(bm))}, gone={sorted(set(bm) - set(fm))})"
        )
    for k in sorted(set(fm) & set(bm)):
        if k.endswith("/exact"):
            if fm[k] != 1.0:
                fails.append(f"{name}: {k} = {fm[k]} (must stay 1.0)")
        elif k.endswith("compiles"):
            if fm[k] != bm[k]:
                fails.append(f"{name}: {k} {fm[k]:.0f} != baseline {bm[k]:.0f} (exact gate)")
        elif not _ratio_ok(fm[k], bm[k], tol):
            fails.append(
                f"{name}: {k} {fm[k]:.3g} vs baseline {bm[k]:.3g} exceeds {tol}x tolerance"
            )
    return fails


def _check_serve_slo(name: str, fresh: dict, base: dict, tol: float) -> list:
    """kernel_roofline gates plus the cache A/B self-gate: the cache-on
    Zipf leg must beat cache-off p99 *within the fresh artifact* — a
    machine-independent claim (same host, same run), so it is exact, not
    ratio-gated."""
    fails = _check_kernel_roofline(name, fresh, base, tol)
    sp = fresh.get("metrics", {}).get("slo/cache/speedup_p99")
    if sp is not None and sp <= 1.0:
        fails.append(
            f"{name}: slo/cache/speedup_p99 = {sp:.3g} "
            "(cache-on Zipf leg must show lower p99 than cache-off)"
        )
    return fails


def _check_training_time(name: str, fresh: dict, base: dict, tol: float) -> list:
    """kernel_roofline gates plus the fit-depth self-gate: the analytic
    compiled sequential depth of the ``fit="fast"`` corridor fit must
    stay strictly below the exact scan's *within the fresh artifact* —
    machine-independent (stage counts, not wall time), so it is exact.
    The ``*/exact`` rule already pins ``fit_depth/fast_sublinear/exact``."""
    fails = _check_kernel_roofline(name, fresh, base, tol)
    m = fresh.get("metrics", {})
    fast, scan = m.get("train/fit_depth/fast/stages"), m.get("train/fit_depth/scan/stages")
    if fast is not None and scan is not None and not fast < scan:
        fails.append(
            f"{name}: fast fit depth {fast:.0f} is not below scan depth {scan:.0f} "
            "(the O(log n) fit claim)"
        )
    return fails


_CHECKERS = {
    "sharded_lookup": _check_sharded_lookup,
    "training_time": _check_training_time,
    "pareto_frontier": _check_pareto_frontier,
    "kernel_roofline": _check_kernel_roofline,
    # same shape/gates as kernel_roofline: metric-set equality, */exact
    # pinned at 1.0, *compiles + trace counts exact, latency by ratio
    "write_workload": _check_kernel_roofline,
    "serve_slo": _check_serve_slo,
}


def check_artifact_data(name: str, fresh: dict, baseline_dir: Path, tol: float) -> list:
    """Diff an in-memory fresh artifact against its committed baseline
    (the path-free core of :func:`check_artifact` — benchmark --check
    flags reuse it without writing the artifact first)."""
    stem = Path(name).stem
    checker = next((fn for key, fn in _CHECKERS.items() if stem.startswith(key)), None)
    if checker is None:
        return [f"{name}: no trend checker for this artifact"]
    base_path = baseline_dir / name
    if not base_path.exists():
        return [f"{name}: no baseline at {base_path} (commit one to start the trend)"]
    with open(base_path) as f:
        base = json.load(f)
    return checker(name, fresh, base, tol)


def check_artifact(fresh_path: Path, baseline_dir: Path, tol: float) -> list:
    with open(fresh_path) as f:
        fresh = json.load(f)
    return check_artifact_data(fresh_path.name, fresh, baseline_dir, tol)


#: numeric-leaf key hints treated as latency/throughput for summaries
_LATENCY_HINTS = ("us", "ns", "per_s", "time", "latency")


def _numeric_leaves(prefix: str, obj, out: dict) -> dict:
    if isinstance(obj, dict):
        for k in sorted(obj):
            _numeric_leaves(f"{prefix}/{k}" if prefix else str(k), obj[k], out)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _numeric_leaves(f"{prefix}[{i}]", v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)
    return out


def summarize_artifact(fresh_path: Path, baseline_dir: Path) -> tuple:
    """``(n_compared, max_latency_ratio, where)`` over the artifact's
    numeric leaves vs its baseline — the one-line PASS summary
    ``benchmarks/run.py --trend`` prints per artifact.  The max ratio is
    taken over latency-ish leaves (``*us*``/``*ns*``/``*per_s*``/...)
    where both sides are positive; ``where`` names the worst leaf."""
    base_path = baseline_dir / fresh_path.name
    with open(fresh_path) as f:
        fresh = _numeric_leaves("", json.load(f), {})
    with open(base_path) as f:
        base = _numeric_leaves("", json.load(f), {})
    common = sorted(set(fresh) & set(base))
    worst, where = 1.0, "-"
    for k in common:
        if not any(h in k.lower() for h in _LATENCY_HINTS):
            continue
        if fresh[k] > 0 and base[k] > 0:
            r = max(fresh[k] / base[k], base[k] / fresh[k])
            if r > worst:
                worst, where = r, k
    return len(common), worst, where


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifacts", nargs="+", help="fresh JSON artifacts to diff")
    ap.add_argument(
        "--baselines", default="benchmarks/baselines", help="committed baseline directory"
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=8.0,
        help="latency ratio allowed either way (generous: CI machines vary)",
    )
    args = ap.parse_args()
    baseline_dir = Path(args.baselines)
    fails = []
    for art in args.artifacts:
        fails += check_artifact(Path(art), baseline_dir, args.tolerance)
    for f in fails:
        print(f"BENCH TREND: {f}", file=sys.stderr)
    if fails:
        print(f"bench-trend: FAILED ({len(fails)} problem(s))", file=sys.stderr)
        sys.exit(1)
    print(f"bench-trend: OK ({len(args.artifacts)} artifacts vs {baseline_dir})")


if __name__ == "__main__":
    main()
