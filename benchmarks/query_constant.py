"""Paper Figures 5-6 (+supp 2-6): constant-space models on Sorted Table
Search procedures.

Grid per (dataset x tier): procedures {BFS, BBS, BFE, K-BFS(6), IBS} with
no model, then models {L, Q, C, KO(15)} with branch-free and branchy
epilogues.  Reports avg query time and the model's reduction factor.

Models go through the unified ``repro.index`` API: the branch-free and
branchy epilogues are the ``xla`` / ``bbs`` backends of the one shared
jitted lookup, not per-model jit closures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import index as ix
from repro.core import model_reduction_factor, search

from .common import bench_tables, emit, queries_for, time_fn


def run(tiers=None, datasets=None):
    results = []
    for bt in bench_tables(datasets=datasets or ("amzn64", "osm"), tiers=tiers):
        table = bt.table
        qs = queries_for(table)
        tj, qj = jnp.asarray(table), jnp.asarray(qs)
        nq = len(qs)

        # --- plain procedures ---
        layout, ranks, h = search.eytzinger_layout(table)
        lj, rj = jnp.asarray(layout), jnp.asarray(ranks)
        plain = {
            "BFS": jax.jit(lambda t, q: search.bfs(t, q)),
            "BBS": jax.jit(lambda t, q: search.bbs(t, q)),
            "K-BFS6": jax.jit(lambda t, q: search.kbfs(t, q, k=6)),
            "IBS": jax.jit(lambda t, q: search.ibs(t, q)),
        }
        for name, fn in plain.items():
            dt = time_fn(fn, tj, qj)
            emit(f"query_const/{bt.name}/{name}", dt / nq * 1e6, "rf=0")
            results.append((bt.name, name, dt / nq))
        dt = time_fn(
            jax.jit(lambda l, r, q: search.bfe(l, r, q, height=h, n=len(table))), lj, rj, qj
        )
        emit(f"query_const/{bt.name}/BFE", dt / nq * 1e6, "rf=0")
        results.append((bt.name, "BFE", dt / nq))

        # --- learned constant-space models (unified Index API) ---
        for spec, label in [
            (ix.AtomicSpec(degree=1), "L"),
            (ix.AtomicSpec(degree=2), "Q"),
            (ix.AtomicSpec(degree=3), "C"),
            (ix.KOSpec(k=15), "15O"),
        ]:
            m = ix.build(spec, table)
            rf = model_reduction_factor(m, table, qs[:2000])
            dt = time_fn(lambda t, q: m.lookup(t, q), tj, qj)
            emit(f"query_const/{bt.name}/{label}-BFS", dt / nq * 1e6, f"rf={rf:.2f}")
            results.append((bt.name, f"{label}-BFS", dt / nq))
            if isinstance(spec, ix.KOSpec):  # branchy epilogue (paper's KO-BBS)
                dt = time_fn(lambda t, q: m.lookup(t, q, backend="bbs"), tj, qj)
                emit(f"query_const/{bt.name}/{label}-BBS", dt / nq * 1e6, f"rf={rf:.2f}")
                results.append((bt.name, f"{label}-BBS", dt / nq))
    return results
