"""Paper Tables 2-5: model training (build) time per element.

Columns mirror the paper: L, Q, C, 15O-BFS, SY-RMI 2%, RMI sweep (SOSD
analogue: avg over the CDFShop grid), RS, PGM — per dataset x tier,
reported in seconds per table element.
"""

from __future__ import annotations

import time

from repro.index import build
from repro.core.sy_rmi import cdfshop_sweep, mine_ub, build_sy_rmi

from .common import bench_tables, emit


def run(tiers=None):
    rows = []
    for bt in bench_tables(tiers=tiers):
        n = len(bt.table)
        times = {}
        for kind, params, label in [
            ("L", {}, "L"),
            ("Q", {}, "Q"),
            ("C", {}, "C"),
            ("KO", {"k": 15}, "15O-BFS"),
            ("RS", {"eps": 32}, "RS"),
            ("PGM", {"eps": 64}, "PGM"),
        ]:
            m = build(kind, bt.table, **params)
            times[label] = m.build_time / n

        t0 = time.perf_counter()
        sweep = cdfshop_sweep(bt.table, max_models=6)
        times["RMI-sweep"] = (time.perf_counter() - t0) / (len(sweep) * n)
        ub = mine_ub(sweep)
        t0 = time.perf_counter()
        build_sy_rmi(bt.table, space_pct=2.0, ub=ub)
        times["SY-RMI2%"] = (time.perf_counter() - t0) / n

        for label, t in times.items():
            emit(f"train_time/{bt.name}/{label}", t * 1e6, f"n={n}")
        rows.append((bt.name, times))
    return rows
