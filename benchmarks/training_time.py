"""Paper Tables 2-5 + the fit-pipeline trend artifact: model build
(training) time per element, measured through the batched grid engine.

The original host-only timing path (per-model ``build_time`` readbacks
plus ``perf_counter`` around the CDFShop sweep) is gone: every leg now
runs through :func:`repro.tune.build_grid`, so the benchmark measures
the pipeline serving actually uses — one vmapped fit trace per kind —
and the three fit modes are directly comparable on the same spec grid:

* ``host`` — the registered per-table builders (numpy greedy; the
  paper's reference build times);
* ``vmap`` — ONE jitted vmapped corridor-scan / leaf-fit trace per
  kind (bit-exact with ``host`` for the corridor kinds);
* ``fast`` — the O(log n)-depth blocked + associative corridor fits
  with the device verified-ε re-measure and lazy host fallback.

(The SY-RMI mining legs live in :mod:`benchmarks.sy_rmi_mining`, which
already runs the sweep through the batched builder.)

Gates (``benchmarks/trend.py::_check_training_time`` against the
committed baseline ``benchmarks/baselines/training_time.json``):

* ``train/exact`` — every grid member under every fit mode answers
  queries identically to the host build (must stay 1.0);
* ``train/fit_depth/fast_sublinear/exact`` — the *analytic* compiled
  sequential depth of the fast fit stays sub-linear in n while the
  exact scan's is linear.  Machine-independent: computed from the
  published stage structure (chunk-long blocked greedy + parity merge
  rounds of associative/segment trees), not from wall time;
* ``train/fit/fast_ok/exact`` + ``train/device_refresh/*`` — the
  verified-ε re-measure passes on the bench distributions and the
  single-program ``device_refresh`` installs an exact shard;
* ``train/compiles`` + trace counts — one fit trace per (kind, fit
  mode) over the whole grid sweep (exact);
* latency legs — generous ratio trend.

``python -m benchmarks.training_time [--json OUT]`` prints the usual
``name,us,derived`` CSV; ``--json`` also writes the trend artifact.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro import index as ix
from repro.core.cdf import ceil_log2, true_ranks
from repro.core.pgm import FAST_CHUNK, pgm_fit_fast
from repro.core.radix_spline import rs_knots_fast
from repro.data import distributions, tables
from repro.dist.sharded_index import ShardedIndex, sharded_lookup
from repro.index import registry
from repro.tune import build_grid
from repro.tune.device_fit import device_refresh

from .common import N_QUERIES, SCALE, emit as _emit, time_fn

_METRICS: dict = {}

#: fit modes the grid sweep measures (``auto`` == vmap on this grid)
FIT_MODES = ("host", "vmap", "fast")


def emit(name: str, value: float, derived: str = ""):
    _METRICS[name] = float(value)
    _emit(name, value, derived)


def _grid_specs(n: int) -> list:
    """The paper-table kind columns as one spec grid: the constant-time
    baselines (L/Q/C), k-optimal BFS, the RMI family at one branching
    factor (two root types so the leaf stage batches), and the corridor
    kinds PGM / RS that also have a ``fit="fast"`` path."""
    b = max(2, min(1024, n // 4))
    return [
        registry.spec_for("L"),
        registry.spec_for("Q"),
        registry.spec_for("C"),
        registry.spec_for("KO", k=15),
        registry.spec_for("RMI", b=b, root_type="linear"),
        registry.spec_for("RMI", b=b, root_type="cubic"),
        registry.spec_for("PGM", eps=64),
        registry.spec_for("PGM", eps=32),
        registry.spec_for("RS", eps=64),
        registry.spec_for("RS", eps=32),
    ]


def _fast_depth(n: int, chunk: int = FAST_CHUNK) -> int:
    """Analytic compiled sequential depth of the fast corridor fit:
    ``chunk`` greedy steps (blocked, vmapped — depth independent of n)
    plus ``ceil_log2(nblocks) + 1`` parity merge rounds, each one
    associative-scan + two segment-tree reductions of depth
    ``ceil_log2(n)``.  Mirrors :func:`repro.core.pgm.pgm_fit_fast`."""
    nblocks = -(-n // chunk)
    rounds = ceil_log2(max(nblocks, 2)) + 1
    return chunk + rounds * (1 + 2 * ceil_log2(max(n, 2)))


def _scan_depth(n: int) -> int:
    """Analytic sequential depth of the exact chunked scan fit: the
    corridor recurrence visits every element in order."""
    return n


def run(n: int | None = None, datasets=("osm",), queries: int | None = None) -> dict:
    _METRICS.clear()
    ix.reset_trace_counts()
    n = int(n) if n else max(1 << 13, int((1 << 17) * SCALE))
    nq = int(queries) if queries else N_QUERIES
    exact = True
    fast_ok = True

    for ds in datasets:
        table = distributions.generate(ds, n, seed=11)
        specs = _grid_specs(n)
        q = tables.make_queries(table, nq, seed=13)
        want = true_ranks(table, q)
        tj, qj = jnp.asarray(table), jnp.asarray(q)

        grids = {}
        for fit in FIT_MODES:
            dt = time_fn(lambda fit=fit: build_grid(specs, table, fit=fit))
            grids[fit] = build_grid(specs, table, fit=fit)
            emit(
                f"train/{ds}/grid_us_per_key/{fit}",
                dt / (len(specs) * n) * 1e6,
                f"n={n};specs={len(specs)}",
            )

        # every member of every fit mode must answer queries exactly
        for fit, built in grids.items():
            for spec, idx in zip(specs, built):
                got = np.asarray(idx.lookup(tj, qj))
                ok = bool((got == want).all())
                exact &= ok
                if not ok:
                    print(f"# train INEXACT: {ds} {spec.display_name()} fit={fit}")

        # the verified-ε re-measure should pass on the bench
        # distributions (fallbacks are for degenerate f64 collisions)
        _, ok_p = pgm_fit_fast(table.astype(np.float64), 32)
        _, ok_r = rs_knots_fast(table.astype(np.float64), 32)
        fast_ok &= bool(ok_p) and bool(ok_r)

    emit("train/exact", float(exact), "grid lookups vs searchsorted, all fit modes")
    emit("train/fit/fast_ok/exact", float(fast_ok), "verified-eps passes, no fallback")

    # ---- analytic compiled-depth account (machine-independent) -----------
    d_fast, d_fast2 = _fast_depth(n), _fast_depth(2 * n)
    d_scan, d_scan2 = _scan_depth(n), _scan_depth(2 * n)
    emit("train/fit_depth/scan/stages", float(d_scan), f"n={n}; O(n) sequential")
    emit("train/fit_depth/fast/stages", float(d_fast), f"n={n}; chunk + log rounds")
    emit("train/fit_depth/fast_2x/stages", float(d_fast2), f"n={2 * n}")
    sublinear = d_fast < d_scan and 4 * (d_fast2 - d_fast) < (d_scan2 - d_scan)
    emit(
        "train/fit_depth/fast_sublinear/exact",
        float(sublinear),
        "fast depth < scan depth and doubling n adds < n/4 stages",
    )

    # ---- device fit-to-serve: one-program shard refresh ------------------
    spec = registry.spec_for("PGM", eps=32)
    sidx = ShardedIndex.build(spec, table, n_shards=4)
    merged = np.asarray(sidx.tables[1][: int(sidx.counts[1])])
    sidx, ok = device_refresh(sidx, 1, merged, 32, fit="fast")  # compile
    sidx2 = ShardedIndex.build(spec, table, n_shards=4)
    t0 = time.perf_counter()
    sidx2, ok = device_refresh(sidx2, 1, merged, 32, fit="fast")
    ok = bool(ok)  # readback syncs the device
    dt = time.perf_counter() - t0
    emit("train/device_refresh/us", dt * 1e6, "fit+assemble+install, one program")
    emit("train/device_refresh/ok/exact", float(ok), "verified-eps install accepted")
    got = np.asarray(sharded_lookup(sidx2, qj, mode="ref"))
    emit(
        "train/device_refresh/exact",
        float(bool((got == want).all())),
        "post-refresh sharded lookups vs searchsorted",
    )

    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    emit("train/compiles", float(sum(traces.values())), "total traces (exact gate)")
    return {
        "metrics": dict(_METRICS),
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write metrics + trace counts as JSON")
    ap.add_argument("--n", type=int, default=None, help="table size (default: bench scale)")
    args = ap.parse_args()
    report = run(n=args.n)
    if args.json:
        with open(args.json, "w") as f:
            f.write(json.dumps(report, indent=2) + "\n")


if __name__ == "__main__":
    main()
