"""Paper Figure 4: SY-RMI identification — per-tier winner histogram,
UB (branching factor per byte), and mining time vs sweep time.

Mining runs on the batched grid builder (:mod:`repro.tune.mining`):
every root type at one branching factor shares a single vmapped
leaf-fit trace and all candidates share the jitted lookup."""

from __future__ import annotations

from repro.tune import mine_sy_rmi

from .common import TIERS, bench_tables, emit


def run():
    out = {}
    for tier in TIERS:
        bts = [bt for bt in bench_tables() if bt.tier == tier]
        res = mine_sy_rmi([bt.table for bt in bts], n_queries=20_000, max_models=6)
        n_total = sum(len(bt.table) for bt in bts)
        emit(
            f"sy_rmi_mining/{tier}/UB",
            res.ub * 1e6,
            f"winner={res.winner_root};time_per_elem={res.mining_time / n_total:.3e}s",
        )
        out[tier] = res
    return out
