"""Shared benchmark infrastructure.

Tiers follow the paper's memory-hierarchy design remapped to the TPU
target (DESIGN.md §3); REPRO_BENCH_SCALE (default 0.125 for the CPU
container) scales key counts, REPRO_BENCH_QUERIES the query batch.
All timings are best-of-3 wall times of jitted, blocked calls.
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax

import repro  # noqa: F401
from repro.data import distributions, tables

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.125"))
N_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", "100000"))
SEED = 7

TIERS = {k: max(1024, int(v * SCALE)) for k, v in tables.TIERS.items()}
DATASETS = distributions.DATASETS


_table_cache = {}


def bench_tables(datasets=DATASETS, tiers=None):
    key = (tuple(datasets), tuple((tiers or TIERS).items()))
    if key not in _table_cache:
        _table_cache[key] = tables.make_bench_tables(
            datasets=datasets, tiers=tiers or TIERS, seed=SEED
        )
    return _table_cache[key]


def queries_for(table: np.ndarray, n: int = None) -> np.ndarray:
    return tables.make_queries(table, n or N_QUERIES, seed=SEED)


def time_fn(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Best-of-reps wall seconds for a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out
        )
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out
        )
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.6g},{derived}")
