"""Paper Figures 7-8 (+supp 8-12): parametric-space models in small space.

Per (dataset x tier): SY-RMI and bi-criteria PGM_M at 0.05% / 0.7% / 2%
space budgets, plus best-under-10% RMI / PGM / RS / B+-tree, with BBS and
BFS baselines — query time vs model space.

Migrated to the unified ``repro.index`` API: every model is built from a
spec and queried through the **shared jitted lookup** — the index is a
pytree argument, not a closure constant, so compiles scale with the
number of *kinds* (plus distinct array structures), not the number of
models.  The old API paid one ``jax.jit`` trace per model; the per-kind
trace counts are reported at the end of the run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import index as ix
from repro.core import search
from repro.core.sy_rmi import cdfshop_sweep, mine_ub
from repro.index import impls

from .common import bench_tables, emit, queries_for, time_fn

SPACE_PCTS = (0.05, 0.7, 2.0)


def run(tiers=None, datasets=None):
    results = []
    ix.reset_trace_counts()
    n_models = 0
    for bt in bench_tables(datasets=datasets or ("amzn64", "osm"), tiers=tiers):
        table = bt.table
        n = len(table)
        table_bytes = n * 8
        qs = queries_for(table)
        tj, qj = jnp.asarray(table), jnp.asarray(qs)
        nq = len(qs)

        for name, fn in [
            ("BBS", jax.jit(lambda t, q: search.bbs(t, q))),
            ("BFS", jax.jit(lambda t, q: search.bfs(t, q))),
        ]:
            dt = time_fn(fn, tj, qj)
            emit(f"query_param/{bt.name}/{name}", dt / nq * 1e6, "space=0")
            results.append((bt.name, name, dt / nq, 0))

        sweep = cdfshop_sweep(table, max_models=6)
        ub = mine_ub(sweep)

        specs = []
        for pct in SPACE_PCTS:
            specs.append((f"SY-RMI{pct}%", ix.SYRMISpec(space_pct=pct, ub=ub)))
            budget = int(pct / 100 * table_bytes)
            specs.append((f"PGM_M{pct}%", ix.PGMBicriteriaSpec(space_budget_bytes=budget)))
        specs.append(("RS", ix.RSSpec(eps=64, r_bits=10)))
        specs.append(("BTree", ix.BTreeSpec(fanout=16)))
        models = [(label, ix.build(spec, table)) for label, spec in specs]
        # best-under-10% from the sweep: wrap the already-fitted model
        # instead of refitting it from a spec
        under10 = [m for m in sweep if m.space_bytes() <= 0.1 * table_bytes]
        if under10:
            best = min(under10, key=lambda m: m.max_eps)
            models.append(("RMI<=10%", impls.rmi_model_to_index("RMI", best, table)))

        for label, m in models:
            n_models += 1
            dt = time_fn(lambda t, q: m.lookup(t, q), tj, qj)
            pct = 100.0 * m.space_bytes() / table_bytes
            emit(f"query_param/{bt.name}/{label}", dt / nq * 1e6, f"space={pct:.4f}%")
            results.append((bt.name, label, dt / nq, pct))

        # fused-kernel leg (smallest tier only — interpret mode off-TPU
        # makes larger sweeps pointless): every learned family through
        # backend="pallas", traces counted by the same compile budget
        if bt.tier == "L1":
            for label, m in models:
                if not any(label.startswith(p) for p in ("SY-RMI2", "PGM_M2", "RS")):
                    continue
                n_models += 1
                dt = time_fn(lambda t, q: m.lookup(t, q, backend="pallas"), tj, qj)
                emit(f"query_param/{bt.name}/{label}/pallas", dt / nq * 1e6, "fused kernel")
                results.append((bt.name, f"{label}/pallas", dt / nq, None))

    traces = ix.trace_counts()
    n_traces = sum(traces.values())
    per_kind = {}
    for (k, _), v in sorted(traces.items()):
        per_kind[k] = per_kind.get(k, 0) + v
    emit("query_param/compiles", n_traces, f"models={n_models};per_kind={per_kind}")
    print(
        f"# shared jitted lookup: {n_models} models -> {n_traces} traces "
        f"across {len(traces)} (kind, backend) entries"
    )
    return results
