"""Paper Figures 7-8 (+supp 8-12): parametric-space models in small space.

Per (dataset x tier): SY-RMI and bi-criteria PGM_M at 0.05% / 0.7% / 2%
space budgets, plus best-under-10% RMI / PGM / RS / B+-tree, with BBS and
BFS baselines — query time vs model space.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_index, search
from repro.core.sy_rmi import cdfshop_sweep, mine_ub, build_sy_rmi

from .common import bench_tables, emit, queries_for, time_fn

SPACE_PCTS = (0.05, 0.7, 2.0)


def run(tiers=None, datasets=None):
    results = []
    for bt in bench_tables(datasets=datasets or ("amzn64", "osm"), tiers=tiers):
        table = bt.table
        n = len(table)
        table_bytes = n * 8
        qs = queries_for(table)
        tj, qj = jnp.asarray(table), jnp.asarray(qs)
        nq = len(qs)

        for name, fn in [
            ("BBS", jax.jit(lambda t, q: search.bbs(t, q))),
            ("BFS", jax.jit(lambda t, q: search.bfs(t, q))),
        ]:
            dt = time_fn(fn, tj, qj)
            emit(f"query_param/{bt.name}/{name}", dt / nq * 1e6, "space=0")
            results.append((bt.name, name, dt / nq, 0))

        sweep = cdfshop_sweep(table, max_models=6)
        ub = mine_ub(sweep)

        models = []
        for pct in SPACE_PCTS:
            models.append((f"SY-RMI{pct}%", build_sy_rmi(table, pct, ub)))
            budget = int(pct / 100 * table_bytes)
            models.append((f"PGM_M{pct}%", build_index("PGM_M", table, space_budget_bytes=budget)))
        # best-under-10% from the sweep + classic indexes
        under10 = [m for m in sweep if m.space_bytes() <= 0.1 * table_bytes]
        if under10:
            best = min(under10, key=lambda m: m.max_eps)
            models.append(("RMI<=10%", best))
        models.append(("RS", build_index("RS", table, eps=64, r_bits=10)))
        models.append(("BTree", build_index("BTREE", table, fanout=16)))

        for label, m in models:
            fn = jax.jit(lambda t, q, m=m: m.predecessor(t, q))
            dt = time_fn(fn, tj, qj)
            pct = 100.0 * m.space_bytes() / table_bytes
            emit(f"query_param/{bt.name}/{label}", dt / nq * 1e6, f"space={pct:.4f}%")
            results.append((bt.name, label, dt / nq, pct))
    return results
