"""Sharded-tier lookup throughput: shard count x kind x backend.

Measures :func:`repro.dist.sharded_lookup` end-to-end (fence route +
capacity-factored all_to_all exchange + local answer + return) against
the single-table ``Index.lookup`` baseline on the concatenated table,
and emits a JSON report with per-configuration throughput plus the
shared-lookup trace counts.

Run on a forced multi-device CPU platform to exercise the collective
paths::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.sharded_lookup --json out.json

``--trace-budget N`` turns the report into a CI gate: the process exits
non-zero when the total number of shared-lookup traces exceeds N
(compile-count regression gate — the whole point of the pytree Index is
that tiers and sweeps do NOT retrace per model).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro import index as ix
from repro.core.cdf import true_ranks
from repro.dist.sharded_index import ShardedIndex, sharded_lookup
from repro.dist.sharding import ShardingCtx

from .common import time_fn

DEFAULT_KINDS = ("RMI", "PGM", "BTREE")
PARAMS = {
    "L": {},
    "Q": {},
    "C": {},
    "KO": {"k": 7},
    "RMI": {"b": 64},
    "SY-RMI": {"space_pct": 2.0, "ub": 0.04},
    "PGM": {"eps": 32},
    "PGM_M": {"space_pct": 2.0, "a": 1.0},
    "RS": {"eps": 16, "r_bits": 8},
    "BTREE": {"fanout": 8},
}


def _mesh_ctx(n_shards: int):
    if n_shards > 1 and len(jax.devices()) >= n_shards:
        mesh = jax.make_mesh((1, n_shards), ("data", "model"))
        return ShardingCtx(mesh=mesh)
    return None


def run(
    n: int = 1 << 14,
    n_queries: int = 1 << 12,
    shard_counts=(1, 2, 4),
    kinds=DEFAULT_KINDS,
    backends=("xla", "bbs", "pallas"),
):
    from repro.core import as_table

    rng = np.random.default_rng(7)
    table = as_table(rng.integers(0, 2**63, size=n, dtype=np.uint64))
    qs = rng.choice(table, size=n_queries).astype(np.uint64)
    want = true_ranks(table, qs)
    tj, qj = jnp.asarray(table), jnp.asarray(qs)

    ix.reset_trace_counts()
    results = []
    for kind in kinds:
        ref_idx = ix.build(kind, table, **PARAMS[kind])
        for backend in backends:
            dt = time_fn(lambda: ref_idx.lookup(tj, qj, backend=backend))
            results.append(
                {
                    "kind": kind,
                    "backend": backend,
                    "mode": "single",
                    "n_shards": 1,
                    "us_per_query": dt / n_queries * 1e6,
                    "qps": n_queries / dt,
                }
            )
        for n_shards in shard_counts:
            sidx = ShardedIndex.build(kind, table, n_shards=n_shards, **PARAMS[kind])
            ctx = _mesh_ctx(n_shards)
            mode = "a2a" if ctx is not None else "ref"
            for backend in backends:
                fn = lambda: sharded_lookup(  # noqa: E731 — timed thunk
                    sidx, qj, ctx, mode=mode, backend=backend, cap_factor=float(n_shards)
                )
                got = np.asarray(fn())
                if not np.array_equal(got, want):
                    raise AssertionError(
                        f"sharded lookup diverged from reference: {kind}/{n_shards}/{backend}",
                    )
                dt = time_fn(fn)
                results.append(
                    {
                        "kind": kind,
                        "backend": backend,
                        "mode": mode,
                        "n_shards": n_shards,
                        "us_per_query": dt / n_queries * 1e6,
                        "qps": n_queries / dt,
                    }
                )
                print(
                    f"sharded_lookup/{kind}/{backend}/{mode}x{n_shards},"
                    f"{results[-1]['us_per_query']:.6g}us"
                )
    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    return {
        "n": int(n),
        "n_queries": int(n_queries),
        "devices": len(jax.devices()),
        "backend_platform": jax.default_backend(),
        "results": results,
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 14, help="table size")
    ap.add_argument("--queries", type=int, default=1 << 12, help="query batch")
    ap.add_argument("--shards", default="1,2,4", help="comma-separated shard counts")
    ap.add_argument("--kinds", default=",".join(DEFAULT_KINDS))
    ap.add_argument("--backends", default="xla,bbs,pallas")
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument(
        "--trace-budget",
        type=int,
        default=None,
        help="fail (exit 1) if total shared-lookup traces exceed this",
    )
    args = ap.parse_args()
    report = run(
        n=args.n,
        n_queries=args.queries,
        shard_counts=tuple(int(s) for s in args.shards.split(",") if s),
        kinds=tuple(k for k in args.kinds.split(",") if k),
        backends=tuple(b for b in args.backends.split(",") if b),
    )
    out = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
    print(out)
    if args.trace_budget is not None and report["total_traces"] > args.trace_budget:
        print(
            f"TRACE BUDGET EXCEEDED: {report['total_traces']} > {args.trace_budget}",
            file=sys.stderr,
        )
        sys.exit(1)


if __name__ == "__main__":
    main()
