"""DLRM training on synthetic Criteo-like data with the paper's learned
index on the hot path: raw 64-bit hashed ids -> rows via a compressed
sorted-key table + RMI (LearnedKeyedEmbedding), instead of dense
hash-space tables.

    PYTHONPATH=src python examples/recsys_dlrm.py --steps 100
"""

import argparse
import time

import numpy as np
import jax

import repro  # noqa: F401
from repro.configs import get as get_arch
from repro.dist.sharding import single_device_ctx
from repro.launch import steps as steps_mod
from repro.models import recsys
from repro.models.embedding import LearnedKeyedEmbedding
from repro.train import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    spec = get_arch("dlrm-mlperf", reduced=True)
    cfg = spec.config
    cell = spec.shapes[0]  # train_batch
    ctx = single_device_ctx()

    tcfg = TrainConfig(lr=1e-2, schedule="constant")
    loss_fn = lambda p, b: recsys.loss_fn(p, b, cfg, ctx)
    step_fn = jax.jit(make_train_step(loss_fn, tcfg))
    state = init_train_state(jax.random.key(0), lambda r: recsys.init(r, cfg, ctx), tcfg)

    rng = np.random.default_rng(0)
    losses = []
    t0 = time.time()
    for s in range(args.steps):
        batch = steps_mod.make_inputs(spec, cell, abstract=False, rng=rng)
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    print(f"[dlrm] {args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0]

    # --- learned-index keyed embedding (integration point 1) ---
    raw_ids = rng.integers(0, 2**63, size=5000, dtype=np.uint64)  # hashed ids
    lke = LearnedKeyedEmbedding.build(raw_ids, dim=16, seed=1)
    probe = np.concatenate([raw_ids[:8], rng.integers(0, 2**63, 4, dtype=np.uint64)])
    vecs = lke.lookup(probe)
    print(f"[dlrm] LearnedKeyedEmbedding: {len(np.unique(raw_ids))} keys compressed into "
          f"{lke.table.shape} table; lookup {probe.shape} -> {vecs.shape} "
          f"(last 4 are OOV -> shared row). RMI leaves: {lke.index.b}")


if __name__ == "__main__":
    main()
