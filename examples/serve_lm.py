"""Serve a small LM with batched requests through the continuous-
batching decode engine (paper-integration: the paged KV pool's page
table is a learned index).

    PYTHONPATH=src python examples/serve_lm.py --requests 12
"""

import argparse
import time

import numpy as np
import jax

import repro  # noqa: F401
from repro.dist.sharding import single_device_ctx
from repro.models import transformer
from repro.models.transformer import LMConfig
from repro.serve.engine import DecodeEngine, Request
from repro.serve.kvcache import PagedPool


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = LMConfig(
        name="serve-demo", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab=4096, dtype="float32",
    )
    ctx = single_device_ctx()
    params = transformer.init(jax.random.key(0), cfg)
    engine = DecodeEngine(params, cfg, ctx, batch_slots=4, max_seq=128)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(3, 10)).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        engine.submit(r)

    t0 = time.time()
    ticks = engine.run_until_drained()
    dt = time.time() - t0
    total_toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve_lm] {len(reqs)} requests, {total_toks} tokens in {ticks} ticks / {dt:.1f}s "
          f"({total_toks / dt:.1f} tok/s, continuous batching over 4 slots)")
    assert all(r.done for r in reqs)

    # paged KV pool with learned-index page table (integration point 5)
    pool = PagedPool(n_pages=64, n_layers=cfg.n_layers, page_size=16,
                     n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim)
    pool.add_sequence(0)
    pool.ensure_capacity(0, 100)
    pages, offs = pool.position_lookup(0, np.array([0, 15, 16, 99]))
    print(
        f"[serve_lm] paged-KV learned lookup: positions [0,15,16,99] -> pages "
        f"{np.asarray(pages)}, offsets {np.asarray(offs)}; pool util {pool.utilization():.2f}"
    )


if __name__ == "__main__":
    main()
