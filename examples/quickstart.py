"""Quickstart: build every learned index in the paper's hierarchy over a
synthetic SOSD-style table through the unified ``repro.index`` API,
query it, and print the time-space-accuracy trade-off (the paper's core
experiment in miniature).

Each index is a JAX pytree of flat arrays built from a hashable spec;
all instances of a kind share ONE jitted lookup (watch the trace count
at the bottom), and every index round-trips through ``save``/``load``.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile
import time

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro import index as ix
from repro.core import model_reduction_factor, true_ranks
from repro.data import distributions, tables


SPECS = [
    ix.AtomicSpec(degree=1),
    ix.AtomicSpec(degree=2),
    ix.AtomicSpec(degree=3),
    ix.KOSpec(k=15),
    ix.RMISpec(b=2048, root_type="linear"),
    ix.SYRMISpec(space_pct=2.0, ub=0.05),
    ix.PGMSpec(eps=64),
    ix.PGMBicriteriaSpec(space_pct=0.05, a=1.0),
    ix.RSSpec(eps=32),
    ix.BTreeSpec(fanout=16),
    ix.GappedSpec(leaf_cap=256, fill=0.75, delta_cap=4096),
]


def main():
    table = distributions.generate("osm", 200_000, seed=0)
    queries = tables.make_queries(table, 50_000, seed=1)
    tj, qj = jnp.asarray(table), jnp.asarray(queries)
    want = true_ranks(table, queries)

    assert tuple(s.kind for s in SPECS) == ix.kinds(), "quickstart covers the registry"
    ix.reset_trace_counts()

    print(f"table: osm-like, {len(table):,} uint64 keys; {len(queries):,} queries\n")
    print(f"{'model':24s} {'space':>12s} {'space%':>8s} {'RF%':>7s} {'us/query':>9s} {'exact':>6s}")

    with tempfile.TemporaryDirectory() as tmp:
        for spec in SPECS:
            m = ix.build(spec, table)
            # npz round-trip: the artifact the serving fleet would load
            path = os.path.join(tmp, f"{spec.kind}.npz")
            m.save(path)
            m = ix.Index.load(path)
            got = np.asarray(m.lookup(tj, qj))
            exact = bool((got == want).all())
            t0 = time.perf_counter()
            m.lookup(tj, qj).block_until_ready()
            dt = time.perf_counter() - t0
            rf = model_reduction_factor(m, table, queries[:2000])
            pct = 100 * m.space_bytes() / (len(table) * 8)
            print(
                f"{m.name:24s} {m.space_bytes():>10,}B {pct:7.3f}% {rf:7.2f}"
                f" {dt / len(queries) * 1e6:9.3f} {str(exact):>6s}"
            )

    n_traces = sum(ix.trace_counts().values())
    print(f"\nshared jitted lookup: {len(SPECS)} models -> {n_traces} traces")
    print("paper's headline: SY-RMI / bi-criteria PGM at 0.05-2% space beat")
    print("plain binary search; space — not accuracy — is the key to efficiency.")

    # --- updatable index: insert_batch / compact (GAPPED only) ----------
    # GAPPED is the one kind that takes writes after the build: keys are
    # absorbed into leaf gaps in place, overflow goes to a sorted delta
    # buffer, and compact() folds the delta back into the leaves.  Reads
    # stay bit-exact against the merged keyset the whole time.
    g = ix.build(ix.GappedSpec(leaf_cap=256, fill=0.75, delta_cap=4096), table)
    rng = np.random.default_rng(7)
    fresh = np.setdiff1d(
        np.unique(rng.integers(1, int(table.max()), 3000, dtype=np.uint64)), table
    )
    g, report = g.insert_batch(fresh)
    merged = np.union1d(table, fresh)
    probe = tables.make_queries(merged, 10_000, seed=3)
    assert (np.asarray(g.lookup(tj, probe)) == true_ranks(merged, probe)).all()
    print(
        f"\nGAPPED ingest: {report.requested} keys -> {report.absorbed} absorbed, "
        f"{report.overflowed} to delta (fill {report.delta_fill:.0%})"
    )
    g = g.compact()  # fold the delta into rebalanced leaves, device-side
    assert (np.asarray(g.lookup(tj, probe)) == true_ranks(merged, probe)).all()
    print(f"after compact(): delta empty, still exact on {len(merged):,} merged keys")

    # --- budget-based selection: don't name an index, name a budget ------
    # repro.tune sweeps the registry-derived candidate grid (batched
    # builds, shared lookup traces), mines the time-space Pareto
    # frontier, and generalises the paper's bi-criteria PGM selection to
    # every registered kind.
    from repro import tune

    cands = tune.sweep(table, queries=queries[:4096], reps=2)
    front = tune.pareto_frontier(cands)
    print(f"\nPareto frontier ({len(cands)} candidates swept):")
    for c in front:
        print(
            f"  {c.spec.display_name():32s} {c.space_bytes:>10,}B "
            f"{c.space_pct_of(len(table)):7.3f}% {c.ns_per_query:8.1f} ns/q"
        )
    print("best spec per space budget (bi-criteria selection, all kinds):")
    for pct in (0.05, 0.7, 2.0, 10.0):
        best = tune.best_candidate_for_budget(cands, len(table), pct)
        assert best is not None and best.space_bytes <= pct / 100 * len(table) * 8
        print(f"  {pct:5.2f}% budget -> {best.spec.display_name()} ({best.space_bytes:,}B)")

    # --- batched builds: many tables, one device fit -------------------
    # fit="auto" is the recommended batch-build mode: every learned
    # family fits its whole batch in ONE jitted trace (RMI leaf
    # least-squares vmapped; PGM/RS greedy corridors as chunked
    # lax.scan, bit-exact with the host builders), and the batch
    # answers queries through one shared lookup trace per backend.
    shards = np.array_split(table, 4)
    bm = tune.build_many(ix.PGMSpec(eps=64), [np.asarray(s) for s in shards], fit="auto")
    outs = np.asarray(bm.lookup(queries[:4096]))
    for i, s in enumerate(shards):
        assert (outs[i] == true_ranks(np.asarray(s), queries[:4096])).all()
    print(f"\nbatched scan-fit build: {bm.n_tables} PGM shards, one fit trace,")
    print("one lookup trace — exact on every shard (fit='auto').")

    # --- O(log n) fast fits ---------------------------------------------
    # fit="fast" swaps the sequential corridor scan for the blocked +
    # associative-merge fit (docs/build_pipeline.md): compiled depth
    # O(chunk + log^2 n) instead of O(n).  Boundaries are not
    # bit-identical to the greedy's, but the model is a verified
    # ε-model (device re-measure, lazy host fallback on degenerate
    # keys) — so predecessor ranks stay exact.
    bf = tune.build_many(ix.PGMSpec(eps=64), [np.asarray(s) for s in shards], fit="fast")
    assert np.array_equal(np.asarray(bf.lookup(queries[:4096])), outs)
    print("fast fit (O(log n) compile depth): ranks still exact on every shard.")

    # --- rebuild while serving: the device fit-to-serve pipeline --------
    # RebuildPolicy(device_refresh=True) closes the host round-trip: a
    # drift-triggered shard refresh compiles pad -> corridor fit ->
    # level assembly -> kernel re-encoding -> ok-gated donated install
    # as ONE device program.  A rejected build (ok=False) leaves the
    # old model serving and falls back to the classic host refresh —
    # device_fit="scan" keeps the demo deterministic (the default
    # "fast" fit may trade a fallback for its O(log n) depth when the
    # refit lands on a segment-capacity boundary).
    from repro import obs

    tier = tune.TunedTier(
        table,
        n_shards=4,
        spec=ix.PGMSpec(eps=64),
        policy=tune.RebuildPolicy(
            shard_refresh_frac=0.005,
            retune_frac=10.0,
            device_refresh=True,
            device_fit="scan",
        ),
    )
    before = obs.metric("device_refreshes").value(kind="PGM", outcome="ok")
    lo, hi = int(table[1_000]), int(table[40_000])
    drift = np.unique(rng.integers(lo, hi, size=1_200, dtype=np.uint64))
    tier.insert_batch(drift)
    merged_t = np.union1d(table, drift)
    probe_t = tables.make_queries(merged_t, 10_000, seed=5)
    assert (np.asarray(tier.lookup(probe_t)) == true_ranks(merged_t, probe_t)).all()
    done = obs.metric("device_refreshes").value(kind="PGM", outcome="ok") - before
    print(
        f"rebuild-while-serving: {len(drift)} drifted keys -> {done:.0f} device "
        "refresh(es), zero host sync on the serve path, lookups exact."
    )


if __name__ == "__main__":
    main()
