"""Quickstart: build every learned index in the paper's hierarchy over a
synthetic SOSD-style table, query it, and print the time-space-accuracy
trade-off (the paper's core experiment in miniature).

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import KINDS, build_index, model_reduction_factor, true_ranks
from repro.data import distributions, tables


def main():
    table = distributions.generate("osm", 200_000, seed=0)
    queries = tables.make_queries(table, 50_000, seed=1)
    tj, qj = jnp.asarray(table), jnp.asarray(queries)
    want = true_ranks(table, queries)

    print(f"table: osm-like, {len(table):,} uint64 keys; {len(queries):,} queries\n")
    print(f"{'model':24s} {'space':>12s} {'space%':>8s} {'RF%':>7s} {'us/query':>9s} {'exact':>6s}")

    for kind, params in [
        ("L", {}), ("Q", {}), ("C", {}),
        ("KO", {"k": 15}),
        ("RMI", {"b": 2048, "root_type": "linear"}),
        ("SY-RMI", {"space_pct": 2.0, "ub": 0.05}),
        ("PGM", {"eps": 64}),
        ("PGM_M", {"space_pct": 0.05, "a": 1.0}),
        ("RS", {"eps": 32}),
        ("BTREE", {"fanout": 16}),
    ]:
        m = build_index(kind, table, **params)
        fn = jax.jit(lambda t, q, m=m: m.predecessor(t, q))
        got = np.asarray(fn(tj, qj))
        exact = bool((got == want).all())
        t0 = time.perf_counter()
        fn(tj, qj).block_until_ready()
        dt = time.perf_counter() - t0
        rf = model_reduction_factor(m, table, queries[:2000])
        pct = 100 * m.space_bytes() / (len(table) * 8)
        print(
            f"{m.name:24s} {m.space_bytes():>10,}B {pct:7.3f}% {rf:7.2f}"
            f" {dt / len(queries) * 1e6:9.3f} {str(exact):>6s}"
        )

    print("\npaper's headline: SY-RMI / bi-criteria PGM at 0.05-2% space beat")
    print("plain binary search; space — not accuracy — is the key to efficiency.")


if __name__ == "__main__":
    main()
