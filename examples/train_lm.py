"""End-to-end LM training driver: data pipeline -> sharded train loop ->
checkpoints -> restart, on the framework's real code paths.

    PYTHONPATH=src python examples/train_lm.py --preset cpu --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # real HW

The ``cpu`` preset (~8M params) finishes a few hundred steps in minutes
on this container; ``100m`` is the same driver at ~100M params for a
real accelerator.  Loss is expected to drop from ~ln(V) as the model
memorises the synthetic Zipf corpus.
"""

import argparse
import time

import jax

import repro  # noqa: F401
from repro.data import pipeline
from repro.dist.sharding import single_device_ctx
from repro.models import transformer
from repro.models.transformer import LMConfig
from repro.train import TrainConfig, init_train_state, loop, make_train_step

PRESETS = {
    "cpu": dict(
        cfg=LMConfig(
            name="demo-8m", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab=8192, q_chunk=128, dtype="float32",
        ),
        batch=8, seq=128,
    ),
    "100m": dict(
        cfg=LMConfig(
            name="demo-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32000, q_chunk=512,
        ),
        batch=32, seq=1024,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = p["cfg"]
    ctx = single_device_ctx()

    print(f"[train_lm] {cfg.name}: ~{cfg.params_count/1e6:.1f}M params")
    corpus = pipeline.synth_corpus(vocab_size=cfg.vocab, n_docs=512, mean_len=256, seed=0)
    batcher = pipeline.TokenBatcher(corpus, batch_size=p["batch"], seq_len=p["seq"], seed=0)

    tcfg = TrainConfig(lr=args.lr, warmup=20, total_steps=args.steps, schedule="warmup_cosine")
    loss_fn = lambda prm, b: transformer.loss_fn(prm, b, cfg, ctx)
    step_fn = jax.jit(make_train_step(loss_fn, tcfg))
    state = init_train_state(jax.random.key(0), lambda r: transformer.init(r, cfg), tcfg)

    t0 = time.time()
    state, report = loop.run(
        step_fn, state, batcher.batch_at,
        loop.LoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10
        ),
    )
    dt = time.time() - t0
    toks = args.steps * p["batch"] * p["seq"]
    print(
        f"[train_lm] {report.steps_run} steps in {dt:.1f}s "
        f"({toks / dt:.0f} tok/s); loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}; "
        f"stragglers: {len(report.straggler_steps)}"
    )
    assert report.losses[-1] < report.losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
